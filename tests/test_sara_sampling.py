"""SARA sampler (Algorithm 2): the Gumbel top-k implementation must realize
the paper's sequential weighted-sampling-without-replacement law."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (offline image)"
)
from hypothesis import given, settings, strategies as st

from repro.core.sampling import (
    gumbel_topk_indices,
    inclusion_probabilities_mc,
    sara_select,
    sara_select_batched,
    sequential_sample_reference,
)


def test_indices_distinct_and_sorted():
    w = jnp.array([5.0, 1.0, 3.0, 0.5, 2.0, 4.0])
    for seed in range(20):
        idx = gumbel_topk_indices(w, 3, jax.random.PRNGKey(seed))
        arr = np.asarray(idx)
        assert len(set(arr.tolist())) == 3
        assert (np.diff(arr) > 0).all()


def test_zero_weights_never_selected():
    w = jnp.array([1.0, 0.0, 2.0, 0.0, 3.0])
    for seed in range(50):
        idx = np.asarray(
            gumbel_topk_indices(w, 3, jax.random.PRNGKey(seed))
        )
        assert 1 not in idx and 3 not in idx


def test_all_zero_weights_fallback_uniform():
    w = jnp.zeros(8)
    seen = set()
    for seed in range(60):
        idx = np.asarray(gumbel_topk_indices(w, 2, jax.random.PRNGKey(seed)))
        seen.update(idx.tolist())
    assert len(seen) == 8  # every index reachable


def test_inclusion_probabilities_match_sequential_law():
    """Gumbel top-k inclusion probs == paper's sequential law (MC, 3 sigma)."""
    w = jnp.array([8.0, 4.0, 2.0, 1.0, 1.0, 0.5])
    r = 3
    n_mc = 20000
    est = np.asarray(
        inclusion_probabilities_mc(w, r, jax.random.PRNGKey(42), n_mc)
    )
    # reference via numpy simulation of Alg.2's sequential law
    rng = np.random.default_rng(7)
    counts = np.zeros(len(w))
    n_ref = 20000
    for _ in range(n_ref):
        for i in sequential_sample_reference(np.asarray(w), r, rng):
            counts[i] += 1
    ref = counts / n_ref
    se = np.sqrt(ref * (1 - ref) * (1 / n_mc + 1 / n_ref))
    assert np.all(np.abs(est - ref) < 4 * se + 0.015), (est, ref)


def test_higher_weight_higher_inclusion():
    w = jnp.array([10.0, 5.0, 2.5, 1.25, 0.6, 0.3, 0.15, 0.075])
    est = np.asarray(
        inclusion_probabilities_mc(w, 3, jax.random.PRNGKey(0), 8000)
    )
    assert (np.diff(est) < 0.02).all()  # monotone non-increasing (noise tol)


def test_sara_select_orthonormal_columns():
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (32, 48))
    u, s, _ = jnp.linalg.svd(g, full_matrices=False)
    p, idx = sara_select(u, s, 8, jax.random.PRNGKey(1))
    ident = p.T @ p
    np.testing.assert_allclose(np.asarray(ident), np.eye(8), atol=1e-5)
    assert (np.diff(np.asarray(idx)) > 0).all()


@given(
    m=st.integers(4, 24),
    r_frac=st.floats(0.1, 1.0),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=30, deadline=None)
def test_property_valid_sample(m, r_frac, seed):
    r = max(1, int(m * r_frac))
    key = jax.random.PRNGKey(seed)
    w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (m,))) + 1e-3
    idx = np.asarray(gumbel_topk_indices(w, r, key))
    assert idx.shape == (r,)
    assert len(set(idx.tolist())) == r
    assert (idx >= 0).all() and (idx < m).all()


def test_r_greater_than_m_raises():
    with pytest.raises(ValueError):
        gumbel_topk_indices(jnp.ones(4), 5, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# batched sampling (the bucket-native refresh engine's primitives)
# ---------------------------------------------------------------------------


@given(
    b=st.integers(1, 6),
    k=st.integers(2, 20),
    r_frac=st.floats(0.1, 1.0),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=25, deadline=None)
def test_property_batched_sara_select_bitexact(b, k, r_frac, seed):
    """Batched sara_select over a (B, k) singular-value stack is
    bit-for-bit with per-slice sara_select given the same folded keys --
    the property the batched refresh engine's trajectories rest on."""
    r = max(1, int(k * r_frac))
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(jax.random.fold_in(key, 1), b)
    u = jax.random.normal(key, (b, 12, k))
    s = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (b, k)))
    p_b, idx_b = sara_select_batched(u, s, r, keys)
    assert p_b.shape == (b, 12, r) and idx_b.shape == (b, r)
    for i in range(b):
        p_i, idx_i = sara_select(u[i], s[i], r, keys[i])
        np.testing.assert_array_equal(np.asarray(p_b[i]), np.asarray(p_i))
        np.testing.assert_array_equal(np.asarray(idx_b[i]), np.asarray(idx_i))
