"""Exactness of the scalable implementations against naive oracles:
chunked online-softmax attention and chunked SSD."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (offline image)"
)
from hypothesis import given, settings, strategies as st

from repro.models.attention import attention, chunked_attention, exact_attention
from repro.models.ssm import ssd_chunked

KEY = jax.random.PRNGKey(0)


def _qkv(B, Sq, Sk, H, KVH, D, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D))
    k = jax.random.normal(ks[1], (B, Sk, KVH, D))
    v = jax.random.normal(ks[2], (B, Sk, KVH, D))
    pos_q = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq)).astype(jnp.int32)
    pos_k = jnp.broadcast_to(jnp.arange(Sk)[None], (B, Sk)).astype(jnp.int32)
    return q, k, v, pos_q, pos_k


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [0, 13])
@pytest.mark.parametrize("cq,ck", [(16, 16), (16, 24), (7, 11)])
def test_chunked_equals_exact(causal, window, cq, ck):
    q, k, v, pq, pk = _qkv(2, 50, 50, 4, 2, 16)
    a = exact_attention(q, k, v, pq, pk, causal=causal, window=window)
    b = chunked_attention(
        q, k, v, pq, pk, causal=causal, window=window, chunk_q=cq, chunk_kv=ck
    )
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_block_skip_correctness():
    """Causal block skipping must not change results."""
    q, k, v, pq, pk = _qkv(1, 64, 64, 2, 2, 16)
    with_skip = chunked_attention(
        q, k, v, pq, pk, causal=True, chunk_q=16, chunk_kv=16,
        skip_masked_blocks=True,
    )
    without = chunked_attention(
        q, k, v, pq, pk, causal=True, chunk_q=16, chunk_kv=16,
        skip_masked_blocks=False,
    )
    np.testing.assert_allclose(
        np.asarray(with_skip), np.asarray(without), atol=1e-5
    )


def test_invalid_positions_masked():
    """kv slots with pos=-1 (unwritten cache) contribute nothing."""
    q, k, v, pq, pk = _qkv(1, 4, 16, 2, 2, 16)
    pk_masked = pk.at[:, 8:].set(-1)
    a = exact_attention(q, k[:, :8], v[:, :8], pq, pk[:, :8], causal=False)
    b = exact_attention(q, k, v, pq, pk_masked, causal=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_decode_single_query():
    q, k, v, pq, pk = _qkv(2, 1, 33, 4, 2, 16)
    pq = jnp.full((2, 1), 32, jnp.int32)
    a = attention(q, k, v, pq, pk, causal=True, impl="exact")
    b = attention(q, k, v, pq, pk, causal=True, impl="chunked", chunk_kv=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@given(
    s=st.integers(4, 40),
    h=st.sampled_from([1, 2, 4]),
    kvh_div=st.sampled_from([1, 2]),
    seed=st.integers(0, 50),
)
@settings(max_examples=15, deadline=None)
def test_property_softmax_rows_sum_preserved(s, h, kvh_div, seed):
    """Attention output is a convex combination of V rows (bounded)."""
    kvh = max(1, h // kvh_div)
    q, k, v, pq, pk = _qkv(1, s, s, h, kvh, 8, seed)
    out = exact_attention(q, k, v, pq, pk, causal=True)
    vmax = float(jnp.max(jnp.abs(v))) + 1e-5
    assert float(jnp.max(jnp.abs(out))) <= vmax + 1e-4


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------


def _naive_ssd(x, dt, a, bm, cm):
    B, S, H, P = x.shape
    N = bm.shape[-1]
    state = np.zeros((B, H, N, P))
    ys = np.zeros((B, S, H, P))
    xn, dtn, bn, cn, an = map(np.asarray, (x, dt, bm, cm, a))
    for t in range(S):
        decay = np.exp(dtn[:, t, :] * an[None, :])
        state = state * decay[:, :, None, None] + np.einsum(
            "bn,bhp->bhnp", bn[:, t], xn[:, t] * dtn[:, t][..., None]
        )
        ys[:, t] = np.einsum("bn,bhnp->bhp", cn[:, t], state)
    return ys, state


@pytest.mark.parametrize("chunk", [4, 8, 16, 33])
@pytest.mark.parametrize("s", [16, 33, 64])
def test_ssd_chunked_equals_recurrence(chunk, s):
    B, H, P, N = 2, 4, 8, 6
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, s, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, s, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    bm = jax.random.normal(ks[3], (B, s, N)) * 0.5
    cm = jax.random.normal(ks[4], (B, s, N)) * 0.5
    y, st_ = ssd_chunked(x, dt, a, bm, cm, chunk=chunk)
    y_ref, st_ref = _naive_ssd(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-3)
    np.testing.assert_allclose(np.asarray(st_), st_ref, atol=1e-3)


def test_ssd_initial_state_continuation():
    """Splitting a sequence across two ssd calls == one call (prefill/decode
    state handoff correctness)."""
    B, S, H, P, N = 1, 32, 2, 8, 4
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    cm = jax.random.normal(ks[4], (B, S, N)) * 0.5
    y_full, st_full = ssd_chunked(x, dt, a, bm, cm, chunk=8)
    half = S // 2
    y1, st1 = ssd_chunked(
        x[:, :half], dt[:, :half], a, bm[:, :half], cm[:, :half], chunk=8
    )
    y2, st2 = ssd_chunked(
        x[:, half:], dt[:, half:], a, bm[:, half:], cm[:, half:], chunk=8,
        init_state=st1,
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], axis=1)),
        np.asarray(y_full), atol=1e-3,
    )
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full), atol=1e-3)
