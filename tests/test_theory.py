"""Property tests for the paper's theory.

Lemma 3.3 (SARA projection error): for P built by SARA from the (noisy)
gradient, E||(I-PP^T) grad||_F^2 <= (1-delta) E||grad||_F^2, with delta the
minimum inclusion probability of any singular direction.  We verify the
bound empirically by Monte-Carlo over the sampler's randomness.

Also: GaLore (dominant) has NO such guarantee -- we exhibit the adversarial
regime (gradient noise dominating) where dominant projection loses the true
gradient directions but SARA retains them in expectation, the motivation for
Theorem 3.4.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (offline image)"
)
from hypothesis import given, settings, strategies as st

from repro.core.projectors import ProjectorConfig, refresh_projector, residual
from repro.core.sampling import inclusion_probabilities_mc


def _mc_residual_ratio(g, method, r, n_mc=64):
    cfg = ProjectorConfig(method=method, rank=r)
    tot = 0.0
    for i in range(n_mc):
        p = refresh_projector(g, jax.random.PRNGKey(i), None, cfg)
        tot += float(jnp.sum(residual(g, p, "left") ** 2))
    return tot / n_mc / float(jnp.sum(g**2))


@given(
    m=st.integers(6, 16),
    n=st.integers(16, 32),
    r_frac=st.floats(0.25, 0.9),
    seed=st.integers(0, 500),
)
@settings(max_examples=15, deadline=None)
def test_lemma_3_3_projection_error_bound(m, n, r_frac, seed):
    r = max(1, int(m * r_frac))
    g = jax.random.normal(jax.random.PRNGKey(seed), (m, n))
    u, s, _ = jnp.linalg.svd(g, full_matrices=False)
    # delta = min inclusion probability (MC estimate over the sampler)
    incl = np.asarray(
        inclusion_probabilities_mc(s, r, jax.random.PRNGKey(seed + 1), 4000)
    )
    delta = max(float(incl.min()) - 0.03, 0.0)  # MC tolerance
    ratio = _mc_residual_ratio(g, "sara", r, n_mc=48)
    assert ratio <= (1 - delta) + 0.05, (ratio, delta)


def test_golore_matches_r_over_m_in_expectation():
    """GoLore's delta_bar = r/m: residual ratio ~ 1 - r/m for random P."""
    m, n, r = 16, 64, 4
    g = jax.random.normal(jax.random.PRNGKey(0), (m, n))
    ratio = _mc_residual_ratio(g, "golore", r, n_mc=200)
    assert abs(ratio - (1 - r / m)) < 0.08, ratio


def test_dominant_zero_residual_on_lowrank_gradient():
    """If rank(G) <= r, dominant projection is lossless."""
    key = jax.random.PRNGKey(1)
    a = jax.random.normal(key, (16, 3))
    b = jax.random.normal(jax.random.fold_in(key, 1), (3, 40))
    g = a @ b
    ratio = _mc_residual_ratio(g, "dominant", 4, n_mc=1)
    assert ratio < 1e-6


def test_sara_retains_weak_directions_dominant_drops_them():
    """The frozen-subspace failure mode: a persistent weak direction is
    *never* captured by dominant selection but has positive probability
    under SARA -- the crux of the convergence gap."""
    m, n, r = 8, 32, 2
    key = jax.random.PRNGKey(2)
    u, _ = jnp.linalg.qr(jax.random.normal(key, (m, m)))
    # two strong noise directions + one weak signal direction
    s = jnp.array([10.0, 9.0, 1.0, 1e-3, 1e-3, 1e-3, 1e-3, 1e-3])
    v = jax.random.normal(jax.random.fold_in(key, 1), (m, n))
    g = u @ (s[:, None] * v)
    weak_dir = u[:, 2]

    def captured(method, n_mc):
        cfg = ProjectorConfig(method=method, rank=r)
        hits = 0
        for i in range(n_mc):
            p = refresh_projector(g, jax.random.PRNGKey(100 + i), None, cfg)
            overlap = float(jnp.sum((p.T @ weak_dir) ** 2))
            hits += overlap > 0.5
        return hits / n_mc

    assert captured("dominant", 20) == 0.0
    assert captured("sara", 200) > 0.02
