"""End-to-end system behavior: the paper's headline claims on a CPU-sized
pretraining run -- SARA explores subspaces (lower adjacent overlap than
dominant selection) and narrows the gap to full-rank Adam."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.core import apply_updates, make_optimizer
from repro.core.metrics import collect_projectors, subspace_overlap
from repro.data.synthetic import SyntheticDataConfig, SyntheticDataset
from repro.models import build_model
from repro.train.loop import train_loop
from repro.train.state import TrainState
from repro.train.step import make_train_step


@pytest.fixture(scope="module")
def task():
    cfg = get_config("llama3-8b", smoke=True).with_(
        dtype=jnp.float32, d_model=96, n_heads=4, head_dim=24, d_ff=192,
    )
    model = build_model(cfg)
    data = SyntheticDataset(
        SyntheticDataConfig(
            vocab_size=cfg.vocab_size, seq_len=64, global_batch=8, seed=3
        )
    )
    return cfg, model, data


def _train(model, data, name, steps, tmp, seed=0, **opt_kw):
    params = model.init(jax.random.PRNGKey(seed))
    opt = make_optimizer(name, params, **opt_kw)
    fns = make_train_step(model, opt, donate=False)
    tc = TrainConfig(
        total_steps=steps, checkpoint_every=0,
        checkpoint_dir=str(tmp / name), seed=seed,
    )
    state = TrainState(params, opt.init(params))
    res = train_loop(
        model, opt, data, tc, fns, state=state, log_every=1000,
        handle_signals=False,
    )
    return res, opt


def test_sara_explores_more_subspaces_than_dominant(task, tmp_path):
    """Fig. 3(a): adjacent refresh overlap lower under SARA than GaLore."""
    cfg, model, data = task
    overlaps = {}
    for name in ("galore-adam", "galore-sara-adam"):
        params = model.init(jax.random.PRNGKey(0))
        opt = make_optimizer(name, params, rank=8, tau=5, lr=2e-3)
        st = TrainState(params, opt.init(params))
        fns = make_train_step(model, opt, donate=False)
        prev = None
        vals = []
        for step in range(25):
            batch = data.batch_at(step)
            if step % 5 == 0:
                st, m = fns["jit_refresh_step"](st, batch)
                projs = collect_projectors(st.opt_state, opt.specs)
                cur = {k: np.asarray(v) for k, v in projs.items()}
                if prev is not None:
                    for k in cur:
                        vals.append(float(np.mean(np.asarray(
                            subspace_overlap(
                                jnp.asarray(prev[k]), jnp.asarray(cur[k])
                            )
                        ))))
                prev = cur
            else:
                st, m = fns["jit_step"](st, batch)
        overlaps[name] = float(np.mean(vals))
    assert overlaps["galore-sara-adam"] < overlaps["galore-adam"], overlaps


def test_sara_closes_gap_to_full_adam(task, tmp_path):
    """Table-1 shape: loss(full) <= loss(sara) + tol and SARA not worse than
    dominant (statistical; small-scale proxy of the PPL ordering)."""
    cfg, model, data = task
    steps = 60
    losses = {}
    for name in ("adam", "galore-sara-adam", "galore-adam"):
        kw = dict(lr=2e-3)
        if name != "adam":
            kw.update(rank=4, tau=10, alpha=1.0)
        res, _ = _train(model, data, name, steps, tmp_path, **kw)
        losses[name] = float(np.mean(res.losses[-10:]))
    assert losses["adam"] <= losses["galore-sara-adam"] + 0.05, losses
    assert losses["galore-sara-adam"] <= losses["galore-adam"] + 0.15, losses


def test_lowrank_memory_claim(task):
    """The deliverable the paper exists for: optimizer state << 2x params."""
    from repro.core import optimizer_memory_report

    cfg, model, data = task
    params = model.init(jax.random.PRNGKey(0))
    full = make_optimizer("adam", params)
    low = make_optimizer("galore-sara-adam", params, rank=4)
    r_full = optimizer_memory_report(params, full.init(params))
    r_low = optimizer_memory_report(params, low.init(params))
    assert r_full["state_to_param_ratio"] > 1.99
    assert r_low["state_to_param_ratio"] < 1.6
