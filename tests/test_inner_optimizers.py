"""Inner stateful optimizers: Adam reference math, factored/quantized
variants, memory footprints."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (offline image)"
)
from hypothesis import given, settings, strategies as st

from repro.core import inner as inner_lib

KEY = jax.random.PRNGKey(0)


def _run(opt, g_seq, shape):
    st_ = opt.init(jnp.zeros(shape))
    outs = []
    for t, g in enumerate(g_seq, start=1):
        d, st_ = opt.update(g, st_, jnp.asarray(t))
        outs.append(d)
    return outs, st_


def test_adam_matches_reference():
    opt = inner_lib.adam(b1=0.9, b2=0.999, eps=1e-8)
    shape = (8, 16)
    gs = [
        jax.random.normal(jax.random.fold_in(KEY, t), shape) for t in range(5)
    ]
    outs, _ = _run(opt, gs, shape)
    # numpy reference
    m = np.zeros(shape)
    v = np.zeros(shape)
    for t, g in enumerate(gs, start=1):
        g = np.asarray(g)
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1 - 0.9**t)
        vh = v / (1 - 0.999**t)
        ref = mh / (np.sqrt(vh) + 1e-8)
        # fp32 (jax) vs fp64 (numpy reference) accumulation
        np.testing.assert_allclose(np.asarray(outs[t - 1]), ref, atol=2e-4)


def test_adam_first_step_is_sign_like():
    opt = inner_lib.adam()
    g = jax.random.normal(KEY, (16,))
    d, _ = opt.update(g, opt.init(g), jnp.asarray(1))
    np.testing.assert_allclose(
        np.asarray(d), np.sign(np.asarray(g)), atol=1e-3
    )


def test_adafactor_factored_second_moment_shapes():
    opt = inner_lib.adafactor()
    x = jnp.zeros((4, 8, 16))
    st_ = opt.init(x)
    assert st_.vr.shape == (4, 8)
    assert st_.vc.shape == (4, 16)
    g = jax.random.normal(KEY, x.shape)
    d, st_ = opt.update(g, st_, jnp.asarray(1))
    assert d.shape == x.shape and np.isfinite(np.asarray(d)).all()


def test_adafactor_memory_sublinear():
    shape = (64, 128)
    full = inner_lib.adam().init(jnp.zeros(shape))
    fact = inner_lib.adafactor().init(jnp.zeros(shape))
    bytes_full = sum(x.size * 4 for x in jax.tree_util.tree_leaves(full))
    bytes_fact = sum(x.size * 4 for x in jax.tree_util.tree_leaves(fact))
    # adafactor keeps m (same) but v is rows+cols instead of rows*cols
    assert bytes_fact < 0.6 * bytes_full


def test_adam_mini_rowwise_v():
    opt = inner_lib.adam_mini()
    x = jnp.zeros((8, 32))
    st_ = opt.init(x)
    assert st_.v.shape == (8,)
    g = jnp.ones((8, 32))
    d, st_ = opt.update(g, st_, jnp.asarray(1))
    # uniform gradient => direction ~ sign
    np.testing.assert_allclose(np.asarray(d), np.ones((8, 32)), atol=1e-2)


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(KEY, (1000,)) * 3.0
    codes, scale = inner_lib.quantize_blockwise(x, signed=True)
    x2 = inner_lib.dequantize_blockwise(codes, scale, signed=True)
    err = np.abs(np.asarray(x - x2))
    # linear 8-bit: error < absmax/127 per block
    assert err.max() < float(jnp.max(jnp.abs(x))) / 127 + 1e-6


def test_quantize_unsigned_nonneg():
    x = jnp.abs(jax.random.normal(KEY, (512,)))
    codes, scale = inner_lib.quantize_blockwise(x, signed=False)
    x2 = inner_lib.dequantize_blockwise(codes, scale, signed=False)
    assert (np.asarray(x2) >= 0).all()
    # sqrt-mapped codes: |err| <= 2*sqrt(v*max)/255 + max/255^2
    mx = float(jnp.max(x))
    bound = 2 * np.sqrt(np.asarray(x) * mx) / 255 + mx / 255**2 + 1e-6
    assert (np.abs(np.asarray(x - x2)) <= bound).all()


def test_quantize_unsigned_preserves_small_values():
    """The reason for sqrt codes: tiny v must not collapse to zero."""
    x = jnp.array([1e-6, 1e-4, 1e-2, 1.0])
    codes, scale = inner_lib.quantize_blockwise(x, signed=False)
    x2 = inner_lib.dequantize_blockwise(codes, scale, signed=False)
    assert float(x2[1]) > 0  # linear codes would round 1e-4/1.0 to 0


def test_adam8bit_tracks_adam():
    """8-bit Adam direction stays close to fp32 Adam over steps."""
    shape = (32, 64)
    opt32 = inner_lib.adam()
    opt8 = inner_lib.adam8bit()
    s32, s8 = opt32.init(jnp.zeros(shape)), opt8.init(jnp.zeros(shape))
    cos = []
    for t in range(1, 8):
        g = jax.random.normal(jax.random.fold_in(KEY, t), shape) * 0.1
        d32, s32 = opt32.update(g, s32, jnp.asarray(t))
        d8, s8 = opt8.update(g, s8, jnp.asarray(t))
        c = float(
            jnp.sum(d32 * d8)
            / (jnp.linalg.norm(d32) * jnp.linalg.norm(d8) + 1e-9)
        )
        cos.append(c)
    assert min(cos) > 0.98, cos


def test_msgd_convention():
    """Paper/GoLore convention: M = (1-b1) M + b1 G."""
    opt = inner_lib.msgd(b1=0.25)
    g = jnp.ones((4,))
    st_ = opt.init(g)
    d1, st_ = opt.update(g, st_, jnp.asarray(1))
    np.testing.assert_allclose(np.asarray(d1), 0.25 * np.ones(4), atol=1e-6)
    d2, st_ = opt.update(g, st_, jnp.asarray(2))
    np.testing.assert_allclose(
        np.asarray(d2), (0.75 * 0.25 + 0.25) * np.ones(4), atol=1e-6
    )


@given(
    shape=st.sampled_from([(7,), (5, 9), (3, 4, 8)]),
    seed=st.integers(0, 100),
    name=st.sampled_from(
        ["adam", "msgd", "adafactor", "adam_mini", "adam8bit"]
    ),
)
@settings(max_examples=25, deadline=None)
def test_property_direction_descends(shape, seed, name):
    """Every inner optimizer's direction positively correlates with g."""
    opt = inner_lib.make_inner(name)
    g = jax.random.normal(jax.random.PRNGKey(seed), shape)
    d, _ = opt.update(g, opt.init(g), jnp.asarray(1))
    assert float(jnp.sum(d * g)) > 0
