"""RMSNorm + galore_project + power_iter Pallas kernels vs oracles
(interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.galore_project.kernel import galore_project
from repro.kernels.galore_project.ref import galore_project_ref
from repro.kernels.power_iter.kernel import power_iter_batched
from repro.kernels.power_iter.ops import power_iter_step
from repro.kernels.power_iter.ref import power_iter_ref
from repro.kernels.rmsnorm.kernel import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("shape", [(8, 128), (4, 16, 256), (100, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    x = (jax.random.normal(KEY, shape) * 2.0).astype(dtype)
    scale = jax.random.normal(jax.random.fold_in(KEY, 1), (shape[-1],))
    out = rmsnorm(x, scale, interpret=True, block_rows=4)
    ref = rmsnorm_ref(x, scale)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


def test_rmsnorm_unit_variance_rows():
    x = jax.random.normal(KEY, (16, 128)) * 5.0
    out = rmsnorm(x, jnp.ones((128,)), interpret=True)
    rms = np.sqrt(np.mean(np.asarray(out) ** 2, axis=-1))
    np.testing.assert_allclose(rms, np.ones(16), atol=1e-3)


@pytest.mark.parametrize("shape", [(8, 128), (2, 16, 256)])
def test_rmsnorm_ops_dispatch(shape):
    """The ops.py backend dispatch (like every other kernel family): the
    jnp ref off-TPU, the Pallas kernel under force_pallas -- parity in
    interpret mode; models/layers.rmsnorm routes through it."""
    from repro.kernels.rmsnorm import ops as rms_ops
    from repro.models import layers as L

    x = (jax.random.normal(KEY, shape) * 2.0).astype(jnp.bfloat16)
    scale = jax.random.normal(jax.random.fold_in(KEY, 2), (shape[-1],))
    ref = rmsnorm_ref(x, scale)
    # CPU dispatch: the ref path, bit-identical
    np.testing.assert_array_equal(
        np.asarray(rms_ops.rmsnorm(x, scale), np.float32),
        np.asarray(ref, np.float32),
    )
    # forced kernel path (interpret): parity within bf16 tolerance
    out = rms_ops.rmsnorm(x, scale, force_pallas=True, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=2e-2
    )
    # the model-layer entry point routes through the dispatch
    np.testing.assert_array_equal(
        np.asarray(L.rmsnorm(x, scale), np.float32),
        np.asarray(ref, np.float32),
    )


@pytest.mark.parametrize("d,n,r", [
    (256, 512, 128), (512, 1024, 64), (100, 200, 16), (384, 768, 256),
])
@pytest.mark.parametrize("gdtype", [jnp.float32, jnp.bfloat16])
def test_galore_project_matches_ref(d, n, r, gdtype):
    ks = jax.random.split(KEY, 4)
    g = (jax.random.normal(ks[0], (d, n)) * 0.1).astype(gdtype)
    p, _ = jnp.linalg.qr(jax.random.normal(ks[1], (d, r)))
    m = jax.random.normal(ks[2], (r, n)) * 0.01
    v = jnp.abs(jax.random.normal(ks[3], (r, n))) * 1e-4
    r1, m1, v1 = galore_project(g, p, m, v, interpret=True)
    r2, m2, v2 = galore_project_ref(g, p, m, v, b1=0.9, b2=0.999)
    tol = 1e-4 if gdtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=tol)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=tol)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=tol)


@pytest.mark.parametrize("b,m,n,kp", [
    (1, 128, 256, 24), (3, 256, 512, 40), (2, 100, 384, 16),
    (4, 384, 640, 72),
])
@pytest.mark.parametrize("gdtype", [jnp.float32, jnp.bfloat16])
def test_power_iter_matches_ref(b, m, n, kp, gdtype):
    """Fused Y = G (G^T Q) kernel (batch grid dim, Z in VMEM scratch) vs
    the jnp oracle, including ragged dims that exercise pick_block."""
    ks = jax.random.split(KEY, 2)
    g = (jax.random.normal(ks[0], (b, m, n)) * 0.1).astype(gdtype)
    q = jax.random.normal(ks[1], (b, m, kp))
    out = power_iter_batched(g, q, interpret=True)
    ref = power_iter_ref(g, q)
    tol = 1e-4 if gdtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=tol, rtol=1e-4
    )


def test_power_iter_accumulates_over_blocks():
    """Multi (m, n)-block grids must equal the single-block result: the Z
    scratch accumulates across both phases' block sweeps."""
    g = jax.random.normal(KEY, (2, 512, 1024)) * 0.1
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 512, 32))
    multi = power_iter_batched(g, q, block_m=128, block_n=256,
                               interpret=True)
    single = power_iter_batched(g, q, block_m=512, block_n=1024,
                                interpret=True)
    np.testing.assert_allclose(
        np.asarray(multi), np.asarray(single), atol=1e-3, rtol=1e-5
    )


def test_power_iter_ops_dispatch():
    """The ops entry point: 2-D inputs get a B=1 batch dim; oversized Z
    scratch falls back to the jnp ref instead of a VMEM blow-up."""
    g = jax.random.normal(KEY, (64, 96)) * 0.1
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (64, 8))
    out = power_iter_step(g, q, force_pallas=True, interpret=True)
    assert out.shape == (64, 8)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(power_iter_ref(g[None], q[None])[0]),
        atol=1e-4,
    )
    # n * kp * 4 over the VMEM budget -> ref path (no pallas lowering)
    big_g = jnp.zeros((1, 8, 1 << 20))
    big_q = jnp.zeros((1, 8, 4))
    assert power_iter_step(
        big_g, big_q, force_pallas=True, interpret=True
    ).shape == (1, 8, 4)


def test_galore_project_accumulates_over_d_blocks():
    """Multi-d-block grid must equal single-block (accumulator scratch)."""
    d, n, r = 512, 256, 32
    ks = jax.random.split(KEY, 4)
    g = jax.random.normal(ks[0], (d, n))
    p, _ = jnp.linalg.qr(jax.random.normal(ks[1], (d, r)))
    m = jnp.zeros((r, n))
    v = jnp.zeros((r, n))
    r_multi, _, _ = galore_project(g, p, m, v, block_d=128, interpret=True)
    r_single, _, _ = galore_project(g, p, m, v, block_d=512, interpret=True)
    np.testing.assert_allclose(
        np.asarray(r_multi), np.asarray(r_single), atol=1e-4
    )
