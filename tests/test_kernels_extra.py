"""RMSNorm + galore_project + power_iter Pallas kernels vs oracles
(interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.galore_project.kernel import galore_project
from repro.kernels.galore_project.ref import galore_project_ref
from repro.kernels.power_iter.kernel import power_iter_batched
from repro.kernels.power_iter.ops import power_iter_step
from repro.kernels.power_iter.ref import power_iter_ref
from repro.kernels.rmsnorm.kernel import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("shape", [(8, 128), (4, 16, 256), (100, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    x = (jax.random.normal(KEY, shape) * 2.0).astype(dtype)
    scale = jax.random.normal(jax.random.fold_in(KEY, 1), (shape[-1],))
    out = rmsnorm(x, scale, interpret=True, block_rows=4)
    ref = rmsnorm_ref(x, scale)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


def test_rmsnorm_unit_variance_rows():
    x = jax.random.normal(KEY, (16, 128)) * 5.0
    out = rmsnorm(x, jnp.ones((128,)), interpret=True)
    rms = np.sqrt(np.mean(np.asarray(out) ** 2, axis=-1))
    np.testing.assert_allclose(rms, np.ones(16), atol=1e-3)


@pytest.mark.parametrize("shape", [(8, 128), (2, 16, 256)])
def test_rmsnorm_ops_dispatch(shape):
    """The ops.py backend dispatch (like every other kernel family): the
    jnp ref off-TPU, the Pallas kernel under force_pallas -- parity in
    interpret mode; models/layers.rmsnorm routes through it."""
    from repro.kernels.rmsnorm import ops as rms_ops
    from repro.models import layers as L

    x = (jax.random.normal(KEY, shape) * 2.0).astype(jnp.bfloat16)
    scale = jax.random.normal(jax.random.fold_in(KEY, 2), (shape[-1],))
    ref = rmsnorm_ref(x, scale)
    # CPU dispatch: the ref path, bit-identical
    np.testing.assert_array_equal(
        np.asarray(rms_ops.rmsnorm(x, scale), np.float32),
        np.asarray(ref, np.float32),
    )
    # forced kernel path (interpret): parity within bf16 tolerance
    out = rms_ops.rmsnorm(x, scale, force_pallas=True, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=2e-2
    )
    # the model-layer entry point routes through the dispatch
    np.testing.assert_array_equal(
        np.asarray(L.rmsnorm(x, scale), np.float32),
        np.asarray(ref, np.float32),
    )


@pytest.mark.parametrize("d,n,r", [
    (256, 512, 128), (512, 1024, 64), (100, 200, 16), (384, 768, 256),
])
@pytest.mark.parametrize("gdtype", [jnp.float32, jnp.bfloat16])
def test_galore_project_matches_ref(d, n, r, gdtype):
    ks = jax.random.split(KEY, 4)
    g = (jax.random.normal(ks[0], (d, n)) * 0.1).astype(gdtype)
    p, _ = jnp.linalg.qr(jax.random.normal(ks[1], (d, r)))
    m = jax.random.normal(ks[2], (r, n)) * 0.01
    v = jnp.abs(jax.random.normal(ks[3], (r, n))) * 1e-4
    r1, m1, v1 = galore_project(g, p, m, v, interpret=True)
    r2, m2, v2 = galore_project_ref(g, p, m, v, b1=0.9, b2=0.999)
    tol = 1e-4 if gdtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=tol)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=tol)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=tol)


@pytest.mark.parametrize("b,m,n,kp", [
    (1, 128, 256, 24), (3, 256, 512, 40), (2, 100, 384, 16),
    (4, 384, 640, 72),
])
@pytest.mark.parametrize("gdtype", [jnp.float32, jnp.bfloat16])
def test_power_iter_matches_ref(b, m, n, kp, gdtype):
    """Fused Y = G (G^T Q) kernel (batch grid dim, Z in VMEM scratch) vs
    the jnp oracle, including ragged dims that exercise pick_block."""
    ks = jax.random.split(KEY, 2)
    g = (jax.random.normal(ks[0], (b, m, n)) * 0.1).astype(gdtype)
    q = jax.random.normal(ks[1], (b, m, kp))
    out = power_iter_batched(g, q, interpret=True)
    ref = power_iter_ref(g, q)
    tol = 1e-4 if gdtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=tol, rtol=1e-4
    )


def test_power_iter_accumulates_over_blocks():
    """Multi (m, n)-block grids must equal the single-block result: the Z
    scratch accumulates across both phases' block sweeps."""
    g = jax.random.normal(KEY, (2, 512, 1024)) * 0.1
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 512, 32))
    multi = power_iter_batched(g, q, block_m=128, block_n=256,
                               interpret=True)
    single = power_iter_batched(g, q, block_m=512, block_n=1024,
                                interpret=True)
    np.testing.assert_allclose(
        np.asarray(multi), np.asarray(single), atol=1e-3, rtol=1e-5
    )


def test_power_iter_ops_dispatch():
    """The ops entry point: 2-D inputs get a B=1 batch dim; oversized Z
    scratch falls back to the jnp ref instead of a VMEM blow-up."""
    g = jax.random.normal(KEY, (64, 96)) * 0.1
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (64, 8))
    out = power_iter_step(g, q, force_pallas=True, interpret=True)
    assert out.shape == (64, 8)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(power_iter_ref(g[None], q[None])[0]),
        atol=1e-4,
    )
    # n * kp * 4 over the VMEM budget -> ref path (no pallas lowering)
    big_g = jnp.zeros((1, 8, 1 << 20))
    big_q = jnp.zeros((1, 8, 4))
    assert power_iter_step(
        big_g, big_q, force_pallas=True, interpret=True
    ).shape == (1, 8, 4)


def test_galore_project_accumulates_over_d_blocks():
    """Multi-d-block grid must equal single-block (accumulator scratch)."""
    d, n, r = 512, 256, 32
    ks = jax.random.split(KEY, 4)
    g = jax.random.normal(ks[0], (d, n))
    p, _ = jnp.linalg.qr(jax.random.normal(ks[1], (d, r)))
    m = jnp.zeros((r, n))
    v = jnp.zeros((r, n))
    r_multi, _, _ = galore_project(g, p, m, v, block_d=128, interpret=True)
    r_single, _, _ = galore_project(g, p, m, v, block_d=512, interpret=True)
    np.testing.assert_allclose(
        np.asarray(r_multi), np.asarray(r_single), atol=1e-4
    )


# ---------------------------------------------------------------------------
# Paged decode attention (kernels/flash_attention_decode) -- ISSUE 10
# ---------------------------------------------------------------------------


def _paged_setup(key, b, mp, ps, h, kvh, d, fills, num_pages=None):
    """Pool + per-slot tables with ragged fills.

    ``fills[i]`` = tokens written for slot i (0 = empty/retired slot).
    Pages are handed out sequentially from 1 (page 0 = trash); unreferenced
    pool pages are filled with garbage so reads through -1 entries or past
    seq_len would show up as mismatches.
    """
    p = num_pages or (1 + b * mp)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
    pages_k = jax.random.normal(ks[1], (p, ps, kvh, d), jnp.float32) * 50.0
    pages_v = jax.random.normal(ks[2], (p, ps, kvh, d), jnp.float32) * 50.0
    table = np.full((b, mp), -1, np.int32)
    nxt = 1
    for i, n in enumerate(fills):
        for j in range((n + ps - 1) // ps):
            table[i, j] = nxt
            nxt += 1
    # overwrite the referenced region with moderate values; garbage stays
    # in unreferenced pages
    used = table[table >= 0]
    pages_k = pages_k.at[used].set(
        jax.random.normal(ks[3], (used.size, ps, kvh, d)) * 0.5
    )
    pages_v = pages_v.at[used].set(
        jax.random.normal(jax.random.fold_in(ks[3], 1),
                          (used.size, ps, kvh, d)) * 0.5
    )
    seq_lens = jnp.asarray(np.asarray(fills, np.int32))
    return q, pages_k, pages_v, jnp.asarray(table), seq_lens


@pytest.mark.serve
@pytest.mark.parametrize("ps,d", [(8, 64), (16, 128), (32, 64)])
@pytest.mark.parametrize("window", [0, 5])
def test_paged_decode_kernel_matches_ref(ps, d, window):
    """Interpret-mode Pallas paged decode == jnp ref across page sizes,
    head dims, sliding windows, and ragged fills (incl. an empty slot)."""
    from repro.kernels.flash_attention_decode.kernel import (
        paged_decode_attention_kernel,
    )
    from repro.kernels.flash_attention_decode.ref import (
        paged_decode_attention_ref,
    )

    b, mp, h, kvh = 4, 3, 4, 2
    fills = [1, ps + 2, 3 * ps - 1, 0]  # partial / multi-page / full / empty
    q, pk, pv, table, lens = _paged_setup(
        jax.random.fold_in(KEY, ps), b, mp, ps, h, kvh, d, fills
    )
    out = paged_decode_attention_kernel(
        q, pk, pv, table, lens, window=window, interpret=True
    )
    ref = paged_decode_attention_ref(q, pk, pv, table, lens, window=window)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-5
    )
    # the empty slot must be exact zeros (not NaN) on both paths
    assert np.all(np.asarray(out)[3] == 0.0)
    assert np.all(np.asarray(ref)[3] == 0.0)


@pytest.mark.serve
def test_paged_decode_ref_matches_exact_attention():
    """The paged ref against the repo's exact_attention oracle: gather the
    pages into a contiguous sequence and compare."""
    from repro.kernels.flash_attention_decode.ref import (
        paged_decode_attention_ref,
    )
    from repro.models.attention import exact_attention

    b, mp, ps, h, kvh, d = 3, 4, 8, 8, 4, 32
    fills = [5, 17, 32]
    q, pk, pv, table, lens = _paged_setup(
        jax.random.fold_in(KEY, 99), b, mp, ps, h, kvh, d, fills
    )
    out = paged_decode_attention_ref(q, pk, pv, table, lens)
    table_np = np.asarray(table)
    for i, n in enumerate(fills):
        safe = np.maximum(table_np[i], 0)
        k_i = np.asarray(pk)[safe].reshape(mp * ps, kvh, d)[None, :n]
        v_i = np.asarray(pv)[safe].reshape(mp * ps, kvh, d)[None, :n]
        ref_i = exact_attention(
            q[i:i + 1], jnp.asarray(k_i), jnp.asarray(v_i),
            jnp.full((1, 1), n - 1, jnp.int32),
            jnp.arange(n, dtype=jnp.int32)[None],
        )
        np.testing.assert_allclose(
            np.asarray(out[i:i + 1]), np.asarray(ref_i), atol=1e-5
        )


@pytest.mark.serve
def test_paged_decode_ops_alignment_gate():
    """ops dispatch: CPU backend takes the ref; force_pallas bypasses the
    backend check but NOT the alignment gate (ragged page size / off-lane
    head dim fall back to the ref instead of an unsupported lowering)."""
    from repro.kernels.flash_attention_decode import ops as fad_ops
    from repro.kernels.flash_attention_decode.ref import (
        paged_decode_attention_ref,
    )

    # aligned: ps % 8 == 0, d % 64 == 0
    q, pk, pv, table, lens = _paged_setup(
        jax.random.fold_in(KEY, 7), 2, 2, 8, 4, 2, 64, [3, 9]
    )
    ref = paged_decode_attention_ref(q, pk, pv, table, lens)
    # CPU dispatch -> ref, bit-identical
    np.testing.assert_array_equal(
        np.asarray(fad_ops.paged_decode_attention(q, pk, pv, table, lens)),
        np.asarray(ref),
    )
    # forced kernel (interpret) -> parity
    np.testing.assert_allclose(
        np.asarray(fad_ops.paged_decode_attention(
            q, pk, pv, table, lens, force_pallas=True, interpret=True
        )),
        np.asarray(ref), atol=2e-5,
    )
    # off-alignment (ps=6, d=48): forced pallas still routes to the ref --
    # identical bits prove no kernel ran
    q2, pk2, pv2, t2, l2 = _paged_setup(
        jax.random.fold_in(KEY, 8), 2, 2, 6, 4, 2, 48, [4, 7]
    )
    ref2 = paged_decode_attention_ref(q2, pk2, pv2, t2, l2)
    np.testing.assert_array_equal(
        np.asarray(fad_ops.paged_decode_attention(
            q2, pk2, pv2, t2, l2, force_pallas=True, interpret=True
        )),
        np.asarray(ref2),
    )


@pytest.mark.serve
def test_paged_decode_attention_requires_single_query():
    from repro.models.attention import paged_decode_attention

    q = jnp.zeros((2, 3, 4, 64))
    pk = jnp.zeros((4, 8, 2, 64))
    table = jnp.zeros((2, 2), jnp.int32)
    lens = jnp.zeros((2,), jnp.int32)
    with pytest.raises(ValueError, match="q_len=1"):
        paged_decode_attention(q, pk, pk, table, lens)
