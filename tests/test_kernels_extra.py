"""RMSNorm + galore_project Pallas kernels vs oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.galore_project.kernel import galore_project
from repro.kernels.galore_project.ref import galore_project_ref
from repro.kernels.rmsnorm.kernel import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("shape", [(8, 128), (4, 16, 256), (100, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    x = (jax.random.normal(KEY, shape) * 2.0).astype(dtype)
    scale = jax.random.normal(jax.random.fold_in(KEY, 1), (shape[-1],))
    out = rmsnorm(x, scale, interpret=True, block_rows=4)
    ref = rmsnorm_ref(x, scale)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


def test_rmsnorm_unit_variance_rows():
    x = jax.random.normal(KEY, (16, 128)) * 5.0
    out = rmsnorm(x, jnp.ones((128,)), interpret=True)
    rms = np.sqrt(np.mean(np.asarray(out) ** 2, axis=-1))
    np.testing.assert_allclose(rms, np.ones(16), atol=1e-3)


@pytest.mark.parametrize("d,n,r", [
    (256, 512, 128), (512, 1024, 64), (100, 200, 16), (384, 768, 256),
])
@pytest.mark.parametrize("gdtype", [jnp.float32, jnp.bfloat16])
def test_galore_project_matches_ref(d, n, r, gdtype):
    ks = jax.random.split(KEY, 4)
    g = (jax.random.normal(ks[0], (d, n)) * 0.1).astype(gdtype)
    p, _ = jnp.linalg.qr(jax.random.normal(ks[1], (d, r)))
    m = jax.random.normal(ks[2], (r, n)) * 0.01
    v = jnp.abs(jax.random.normal(ks[3], (r, n))) * 1e-4
    r1, m1, v1 = galore_project(g, p, m, v, interpret=True)
    r2, m2, v2 = galore_project_ref(g, p, m, v, b1=0.9, b2=0.999)
    tol = 1e-4 if gdtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=tol)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=tol)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=tol)


def test_galore_project_accumulates_over_d_blocks():
    """Multi-d-block grid must equal single-block (accumulator scratch)."""
    d, n, r = 512, 256, 32
    ks = jax.random.split(KEY, 4)
    g = jax.random.normal(ks[0], (d, n))
    p, _ = jnp.linalg.qr(jax.random.normal(ks[1], (d, r)))
    m = jnp.zeros((r, n))
    v = jnp.zeros((r, n))
    r_multi, _, _ = galore_project(g, p, m, v, block_d=128, interpret=True)
    r_single, _, _ = galore_project(g, p, m, v, block_d=512, interpret=True)
    np.testing.assert_allclose(
        np.asarray(r_multi), np.asarray(r_single), atol=1e-4
    )
