"""Subspace metrics (GARD18 overlap, update spectra, effective rank)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (offline image)"
)
from hypothesis import given, settings, strategies as st

from repro.core.metrics import (
    OverlapTracker,
    effective_rank,
    subspace_overlap,
    update_singular_spectrum,
)

KEY = jax.random.PRNGKey(0)


def _orth(m, r, seed=0):
    q, _ = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(seed), (m, r)))
    return q


def test_overlap_identity():
    u = _orth(16, 4)
    assert abs(float(subspace_overlap(u, u)) - 1.0) < 1e-5


def test_overlap_orthogonal_subspaces():
    q = _orth(16, 8)
    u, v = q[:, :4], q[:, 4:]
    assert float(subspace_overlap(u, v)) < 1e-6


def test_overlap_invariant_to_basis_rotation():
    u = _orth(16, 4, 0)
    v = _orth(16, 4, 1)
    rot = _orth(4, 4, 2)
    o1 = float(subspace_overlap(u, v))
    o2 = float(subspace_overlap(u, v @ rot))
    assert abs(o1 - o2) < 1e-5


@given(m=st.integers(6, 24), r=st.integers(1, 6), seed=st.integers(0, 99))
@settings(max_examples=25, deadline=None)
def test_property_overlap_in_unit_interval(m, r, seed):
    r = min(r, m)
    u = _orth(m, r, seed)
    v = _orth(m, r, seed + 1)
    o = float(subspace_overlap(u, v))
    assert -1e-6 <= o <= 1.0 + 1e-6


def test_update_spectrum_normalized_descending():
    w0 = jax.random.normal(KEY, (24, 32))
    w1 = w0 + 0.1 * jax.random.normal(jax.random.fold_in(KEY, 1), (24, 32))
    s = np.asarray(update_singular_spectrum(w0, w1))
    assert abs(s[0] - 1.0) < 1e-5
    assert (np.diff(s) <= 1e-6).all()


def test_effective_rank_extremes():
    flat = jnp.ones(16)
    spike = jnp.zeros(16).at[0].set(1.0)
    assert float(effective_rank(flat)) > 15.0
    assert float(effective_rank(spike)) < 1.1


def test_lowrank_update_has_low_effective_rank():
    """A rank-r update's spectrum has ~r effective rank (Fig. 4 mechanics)."""
    p = _orth(32, 4)
    delta = p @ jax.random.normal(KEY, (4, 48))
    s = update_singular_spectrum(jnp.zeros((32, 48)), delta)
    assert float(effective_rank(s)) < 6.0


def test_tracker_series():
    tr = OverlapTracker()
    p0 = {"layer0": _orth(16, 4, 0)}
    tr.set_anchor(p0)
    tr.observe(p0)
    tr.observe({"layer0": _orth(16, 4, 1)})
    tr.observe({"layer0": _orth(16, 4, 2)})
    s = tr.summary()
    assert "layer0" in s
    assert 0 <= s["layer0"]["adjacent_mean"] <= 1
    assert 0 <= s["layer0"]["anchor_last"] <= 1
