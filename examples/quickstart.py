"""Quickstart: pretrain a tiny LLaMA with GaLore-SARA-Adam in ~a minute.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.core import make_optimizer, optimizer_memory_report
from repro.data.synthetic import SyntheticDataConfig, SyntheticDataset
from repro.models import build_model, count_params
from repro.train.loop import train_loop
from repro.train.step import make_train_step


def main():
    cfg = get_config("llama3-8b", smoke=True).with_(dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"model: {count_params(params) / 1e6:.2f}M params")

    # The paper's optimizer: importance-sampled low-rank subspace + Adam.
    opt = make_optimizer(
        "galore-sara-adam", params, rank=8, tau=20, lr=2e-3, alpha=1.0
    )
    rep = optimizer_memory_report(params, opt.init(params))
    print(
        f"optimizer state/param ratio: {rep['state_to_param_ratio']:.2f} "
        f"(full Adam would be 2.0)"
    )

    data = SyntheticDataset(SyntheticDataConfig(
        vocab_size=cfg.vocab_size, seq_len=64, global_batch=8
    ))
    print(f"bigram entropy floor: {data.bigram_entropy():.3f}")

    tc = TrainConfig(
        total_steps=120, checkpoint_every=50,
        checkpoint_dir="/tmp/repro_quickstart",
    )
    fns = make_train_step(model, opt, donate=False)
    res = train_loop(model, opt, data, tc, fns, log_every=20)
    print(f"loss: {res.losses[0]:.3f} -> {res.losses[-1]:.3f}")
    for rec in res.history:
        print({k: round(v, 4) for k, v in rec.items()})


if __name__ == "__main__":
    main()
