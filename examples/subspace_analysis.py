"""Reproduce the paper's subspace phenomenology (Figs. 2-4) numerically:

  1. frozen dominant subspace: adjacent overlap under GaLore rises with step;
  2. SARA keeps adjacent overlap low (more exploration);
  3. SARA's accumulated updates have higher effective rank.

    PYTHONPATH=src python examples/subspace_analysis.py
"""
import jax
import numpy as np

from benchmarks.common import bench_data, bench_model, train_once
from repro.core.metrics import effective_rank, update_singular_spectrum


def main():
    cfg, model = bench_model()
    data = bench_data(cfg)
    params0 = model.init(jax.random.PRNGKey(0))

    print("== adjacent subspace overlap over refreshes (Fig. 2/3a) ==")
    series = {}
    for name in ("galore-adam", "galore-sara-adam"):
        out = train_once(
            model, data, name, steps=200, tau=10, track_overlap=True
        )
        series[name] = out
        ovl = np.array(out["overlaps"])
        print(f"  {name:20s} first3={ovl[:3].round(3).tolist()} "
              f"last3={ovl[-3:].round(3).tolist()} mean={ovl.mean():.3f}")
    print("  -> SARA adjacent overlap should be consistently lower.")

    print("\n== update effective rank (Fig. 4) ==")
    for name, out in series.items():
        w0 = params0["blocks"]["q_proj"][0]
        w1 = out["state"].params["blocks"]["q_proj"][0]
        spec = update_singular_spectrum(w0, w1)
        print(f"  {name:20s} effective_rank={float(effective_rank(spec)):.2f}"
              f" top8_mass={float(np.asarray(spec)[:8].sum() / np.asarray(spec).sum()):.3f}")
    print("  -> SARA spreads update energy over more directions.")


if __name__ == "__main__":
    main()
