"""Batched serving: prefill a batch of prompts, decode with the ring-buffer
KV cache (or SSM state for mamba/hymba archs).

    PYTHONPATH=src python examples/serve_decode.py --arch llama3-8b
    PYTHONPATH=src python examples/serve_decode.py --arch mamba2-370m
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, list_archs
from repro.models import build_model, count_params
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    # smoke-size config: this is a CPU container (full configs are exercised
    # by the dry-run); the serving path is identical.
    cfg = get_config(args.arch, smoke=True).with_(dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[serve] {args.arch} ({count_params(params) / 1e6:.2f}M smoke)")

    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size
    )}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1), (args.batch, 8, cfg.d_model)
        ) * 0.1
    if cfg.family == "audio":
        batch["frame_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1),
            (args.batch, cfg.enc_frames, cfg.d_model),
        ) * 0.1

    eng = ServeEngine(
        model, params, capacity=args.prompt_len + args.new_tokens + 8
    )
    t0 = time.perf_counter()
    out = eng.generate(
        batch, max_new_tokens=args.new_tokens,
        greedy=(args.temperature == 0.0), temperature=max(args.temperature, 1e-3),
    )
    dt = time.perf_counter() - t0
    toks = args.batch * args.new_tokens
    print(f"[serve] {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    for i in range(min(args.batch, 2)):
        print(f"  seq{i}: {out.tokens[i].tolist()}")


if __name__ == "__main__":
    main()
