"""End-to-end pretraining driver.

Presets:
  cpu-small  (default) -- ~10M-param LLaMA, 200 steps; minutes on this CPU
                          container.  Demonstrates the full production path:
                          checkpoints, monitors, staggered SARA refresh.
  llama-60m             -- the paper's LLaMA-60M configuration (Table 1 row):
                          intended for a real accelerator; runs on CPU too,
                          just slowly.

    PYTHONPATH=src python examples/pretrain_lm.py --preset cpu-small \
        --optimizer galore-sara-adam --steps 200
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import make_optimizer
from repro.core.schedules import cosine_with_warmup
from repro.data.synthetic import SyntheticDataConfig, SyntheticDataset
from repro.models import build_model, count_params
from repro.train.loop import train_loop
from repro.train.step import make_train_step

PRESETS = {
    # ~10M params
    "cpu-small": dict(
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
        d_ff=688, vocab_size=2048, seq=128, batch=8, rank=32, tau=50,
    ),
    # the paper's LLaMA-60M (vocab reduced to the synthetic corpus size)
    "llama-60m": dict(
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
        d_ff=1376, vocab_size=32100, seq=256, batch=32, rank=128, tau=200,
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu-small", choices=list(PRESETS))
    ap.add_argument("--optimizer", default="galore-sara-adam")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_pretrain")
    ap.add_argument("--refresh-groups", type=int, default=1)
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = ModelConfig(
        arch_id=f"llama-{args.preset}", family="dense",
        n_layers=p["n_layers"], d_model=p["d_model"], n_heads=p["n_heads"],
        n_kv_heads=p["n_kv_heads"], head_dim=p["head_dim"], d_ff=p["d_ff"],
        vocab_size=p["vocab_size"], dtype=jnp.float32,
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[pretrain] {count_params(params) / 1e6:.1f}M params, "
          f"optimizer={args.optimizer}")

    kw = dict(
        lr=args.lr,
        lr_schedule=cosine_with_warmup(args.lr, args.warmup, args.steps),
        grad_clip_norm=1.0,
    )
    if args.optimizer != "adam":
        kw.update(rank=p["rank"], tau=p["tau"], alpha=0.25,
                  refresh_groups=args.refresh_groups)
    opt = make_optimizer(args.optimizer, params, **kw)

    data = SyntheticDataset(SyntheticDataConfig(
        vocab_size=cfg.vocab_size, seq_len=p["seq"], global_batch=p["batch"]
    ))
    tc = TrainConfig(
        total_steps=args.steps, checkpoint_every=max(args.steps // 4, 1),
        checkpoint_dir=args.ckpt_dir, async_checkpoint=True,
    )
    fns = make_train_step(model, opt, donate=False)
    res = train_loop(
        model, opt, data, tc, fns, log_every=max(args.steps // 10, 1),
        track_subspace=(args.optimizer != "adam"),
    )
    print(f"[pretrain] final loss {res.losses[-1]:.4f} "
          f"(floor {data.bigram_entropy():.4f})")
    if hasattr(res, "subspace"):
        for name, vals in list(res.subspace.summary().items())[:3]:
            print(f"[subspace] {name}: {vals}")


if __name__ == "__main__":
    main()
